"""Continuous-batching serve benchmark: Poisson arrivals, ragged prompts.

Drives the slot-scheduled engine with a synthetic open-loop trace (requests
arrive at Poisson times, with random prompt lengths and token budgets) and
reports decode throughput plus per-request latency percentiles — the
throughput/latency axis the ROADMAP's serving scenarios build on.

Run:  PYTHONPATH=src python benchmarks/serve_throughput.py \
          [--arch llama3-8b] [--requests 24] [--rate 20] [--slots 4] \
          [--mesh 2x4] [--json BENCH_serve_throughput.json]

``--json`` writes the summary record CI uploads as a workflow artifact
(the ``BENCH_*.json`` perf trajectory).
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import model as M
from repro.serve.engine import ContinuousBatchingEngine


def build_trace(rng, n, rate, max_prompt, max_new):
    """Poisson process: exponential inter-arrival gaps at ``rate`` req/s."""
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(gaps)
    prompts = [rng.integers(0, 2**30, size=rng.integers(4, max_prompt + 1))
               for _ in range(n)]
    budgets = rng.integers(max(1, max_new // 2), max_new + 1, size=n)
    return arrivals, prompts, budgets


def percentile(sorted_vals, q):
    if not sorted_vals:
        return float("nan")
    i = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[i]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean arrival rate, requests/second")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help='serve over a (data, model) mesh, e.g. "2x4"')
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the summary record as JSON")
    args = ap.parse_args()

    from repro.launch.serve import make_serve_runtime
    cfg = registry.get(args.arch).reduced()
    params = M.init_params(jax.random.key(0), cfg)
    max_len = args.max_prompt + args.max_new + 1
    eng = ContinuousBatchingEngine(cfg, params, n_slots=args.slots,
                                   max_len=max_len,
                                   rt=make_serve_runtime(args.mesh))

    rng = np.random.default_rng(args.seed)
    arrivals, prompts, budgets = build_trace(
        rng, args.requests, args.rate, args.max_prompt, args.max_new)
    prompts = [(p % cfg.vocab_size).tolist() for p in prompts]

    # warm the compile caches (budget 2 so the batched decode step compiles
    # too, not just prefill) so the measured run is steady-state serving;
    # one prompt per reachable prefill bucket keeps mid-trace compiles out
    # of the measured p99/TTFT
    b = eng.prefill_bucket
    warm_lens = sorted({min(n, args.max_prompt)
                        for n in range(b, args.max_prompt + b, b)})
    warm = [list(range(max(1, n))) for n in warm_lens]
    eng.generate_all(warm, [2] * len(warm))

    reqs = []
    eng.reset_clock()
    t0 = time.perf_counter()
    next_i = 0
    while next_i < len(prompts) or eng.scheduler.has_work():
        now = time.perf_counter() - t0
        while next_i < len(prompts) and arrivals[next_i] <= now:
            reqs.append(eng.submit(prompts[next_i], int(budgets[next_i]),
                                   arrival_time=float(arrivals[next_i])))
            next_i += 1
        if not eng.step() and next_i < len(prompts):
            # idle: nothing resident yet, next arrival still in the future
            time.sleep(min(0.001, max(0.0, arrivals[next_i] - now)))
    wall = time.perf_counter() - t0

    gen = sum(len(r.output) for r in reqs)
    lat = sorted(r.finish_time - r.arrival_time for r in reqs)
    ttft = sorted(r.first_token_time - r.arrival_time for r in reqs)
    print(f"arch={cfg.name} slots={args.slots} requests={args.requests} "
          f"rate={args.rate}/s prompts 4..{args.max_prompt} "
          f"new {max(1, args.max_new//2)}..{args.max_new}")
    print(f"wall {wall:.2f}s | generated {gen} tokens | "
          f"throughput {gen / wall:.1f} tok/s")
    print(f"latency  p50 {percentile(lat, 0.50)*1e3:7.1f} ms   "
          f"p99 {percentile(lat, 0.99)*1e3:7.1f} ms")
    print(f"TTFT     p50 {percentile(ttft, 0.50)*1e3:7.1f} ms   "
          f"p99 {percentile(ttft, 0.99)*1e3:7.1f} ms")
    if args.json:
        rec = {"bench": "serve_throughput", "arch": cfg.name,
               "slots": args.slots, "requests": args.requests,
               "rate_req_s": args.rate, "mesh": args.mesh,
               "seed": args.seed, "wall_s": wall, "generated_tokens": gen,
               "throughput_tok_s": gen / wall,
               "latency_p50_ms": percentile(lat, 0.50) * 1e3,
               "latency_p99_ms": percentile(lat, 0.99) * 1e3,
               "ttft_p50_ms": percentile(ttft, 0.50) * 1e3,
               "ttft_p99_ms": percentile(ttft, 0.99) * 1e3}
        with open(args.json, "w") as f:
            json.dump(rec, f, indent=1)
        print("wrote", args.json)


if __name__ == "__main__":
    main()
